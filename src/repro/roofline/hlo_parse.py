"""Post-SPMD HLO text analyzer: scan-corrected FLOPs, HBM bytes, collectives.

Why not cost_analysis(): XLA's HloCostAnalysis counts a ``while`` body ONCE,
but our models scan over layers (x26..x56) and attention blocks — calibration
(tests/test_roofline.py) shows an exact /trip_count undercount.  This module
reconstructs the computation call graph, estimates each while's trip count
from the loop-bound constant in its condition, and weights every computation
by its execution multiplicity.

Per-device quantities (the module is the post-partitioning per-device
program):
  * ``dot_flops``    — 2 x prod(result) x prod(contracting dims), x mult
  * ``conv_flops``   — 2 x prod(result) x prod(kernel)/C_out,     x mult
  * ``hbm_bytes``    — per top-level instruction: result + operand bytes
                       (fusion interiors excluded — fused ops don't touch HBM)
  * ``collectives``  — kind, per-device buffer bytes, group size, x mult,
                       plus ring-model wire bytes
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\]{},]+))\s+"
    r"([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\]{},]+))")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[[\d,]+\](?:T\([\d,]+\))?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_ANNOT_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_WHILE_ATTRS_RE = re.compile(
    r"condition=%?([\w.\-]+)|body=%?([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_CALLED_SET_RE = re.compile(r"called_computations=\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute", "all-reduce-start", "all-gather-start",
                   "collective-permute-start"}
# HBM-traffic accounting models TPU fusion: elementwise chains fuse into
# their producers/consumers, so only "boundary" ops move HBM bytes.  This is
# a whitelist, not a blacklist — XLA:CPU leaves far more ops unfused than a
# TPU compile would, and counting them all inflates the memory term ~10x
# (calibrated on the rwkv6 scan, whose Pallas kernel keeps state in VMEM).
_BYTES_OPS = {"dot", "dot_general", "convolution", "fusion", "custom-call",
              "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
              "reduce", "reduce-window", "select-and-scatter", "sort",
              "transpose", "concatenate", "pad", "reverse", "copy",
              "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute", "cholesky", "triangular-solve", "fft"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_ATOM.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _dims_of(shape_str: str) -> List[int]:
    m = _SHAPE_ATOM.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    multiplicity: float = 1.0

    @property
    def wire_bytes(self) -> float:
        """Ring-model per-device bytes on the wire (x multiplicity)."""
        p = max(self.group_size, 2)
        n = self.result_bytes
        if self.kind == "all-reduce":
            per = 2.0 * n * (p - 1) / p
        elif self.kind == "all-gather":
            per = n * (p - 1) / p
        elif self.kind == "reduce-scatter":
            per = n * (p - 1)
        elif self.kind == "all-to-all":
            per = n * (p - 1) / p
        else:                              # collective-permute
            per = float(n)
        return per * self.multiplicity


@dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclass
class _Comp:
    name: str
    is_entry: bool
    instrs: List[_Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> shape str


@dataclass
class Contributor:
    comp: str
    opcode: str
    shape: str
    multiplicity: float
    flops: float = 0.0
    bytes: float = 0.0
    meta: str = ""


@dataclass
class ModuleStats:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: List[CollectiveOp] = field(default_factory=list)
    while_trip_counts: List[int] = field(default_factory=list)
    contributors: List[Contributor] = field(default_factory=list)

    def top_flops(self, n=15):
        return sorted((c for c in self.contributors if c.flops),
                      key=lambda c: -c.flops)[:n]

    def top_bytes(self, n=15):
        return sorted((c for c in self.contributors if c.bytes),
                      key=lambda c: -c.bytes)[:n]

    def top_collectives(self, n=15):
        return sorted(self.collectives, key=lambda c: -c.wire_bytes)[:n]

    @property
    def flops(self) -> float:
        return self.dot_flops + self.conv_flops

    @property
    def wire_bytes_total(self) -> float:
        return sum(c.wire_bytes for c in self.collectives)

    def collective_summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"count": 0.0, "buffer_bytes": 0.0, "wire_bytes": 0.0})
        for c in self.collectives:
            out[c.kind]["count"] += c.multiplicity
            out[c.kind]["buffer_bytes"] += c.result_bytes * c.multiplicity
            out[c.kind]["wire_bytes"] += c.wire_bytes
        return {k: dict(v) for k, v in out.items()}


def _parse_computations(text: str) -> List[_Comp]:
    comps: List[_Comp] = []
    cur: Optional[_Comp] = None
    depth = 0
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = _Comp(name=m.group(2), is_entry=bool(m.group(1)))
                for pname, pshape in _PARAM_RE.findall(m.group(3)):
                    cur.symbols[pname] = pshape
                comps.append(cur)
                depth = 1
            continue
        stripped = line.strip()
        if stripped == "}":
            depth -= 1
            if depth == 0:
                cur = None
            continue
        if stripped.endswith("{"):
            depth += 1
        im = _INSTR_RE.match(line)
        if im:
            name, shape, opcode = im.group(1), im.group(2), im.group(3)
            cur.symbols[name] = shape
            cur.instrs.append(_Instr(name, shape, opcode, line))
    return comps


def _dot_flops(instr: _Instr, comp: _Comp) -> float:
    _, res_elems = 0, _shape_elems_bytes(instr.shape)[0]
    cm = _LHS_CONTRACT_RE.search(instr.line)
    cdims = [int(x) for x in cm.group(1).split(",")] if cm and cm.group(1) else []
    # first operand (lhs) shape
    paren = instr.line.index("(")
    ops = _OPERAND_RE.findall(instr.line[paren:instr.line.find(")", paren)])
    contract = 1
    if ops:
        lhs_shape = comp.symbols.get(ops[0], "")
        dims = _dims_of(lhs_shape)
        for cd in cdims:
            if cd < len(dims):
                contract *= dims[cd]
    return 2.0 * res_elems * max(contract, 1)


def _conv_flops(instr: _Instr, comp: _Comp) -> float:
    res_elems = _shape_elems_bytes(instr.shape)[0]
    paren = instr.line.index("(")
    ops = _OPERAND_RE.findall(instr.line[paren:instr.line.find(")", paren)])
    if len(ops) < 2:
        return 2.0 * res_elems
    kdims = _dims_of(comp.symbols.get(ops[1], ""))
    rdims = _dims_of(instr.shape)
    if not kdims or not rdims:
        return 2.0 * res_elems
    co = rdims[-1] if rdims[-1] in kdims else kdims[-1]
    kernel_elems = 1
    for d in kdims:
        kernel_elems *= d
    return 2.0 * res_elems * kernel_elems / max(co, 1)


def _instr_bytes(instr: _Instr, comp: _Comp) -> float:
    if instr.opcode not in _BYTES_OPS:
        return 0.0
    total = _shape_elems_bytes(instr.shape)[1]
    paren = instr.line.index("(")
    close = instr.line.find(")", paren)
    for op in _OPERAND_RE.findall(instr.line[paren:close if close > 0 else None]):
        shp = comp.symbols.get(op)
        if shp:
            total += _shape_elems_bytes(shp)[1]
    return float(total)


def _trip_count(while_line: str, cond: Optional[_Comp]) -> int:
    """Primary: XLA's known_trip_count backend_config on the while op.
    Fallback: largest integer constant in the condition computation."""
    m = _TRIP_ANNOT_RE.search(while_line)
    if m:
        return int(m.group(1))
    if cond is None:
        return 1
    vals = [int(c.group(1)) for ins in cond.instrs
            for c in _CONST_INT_RE.finditer(ins.line)]
    return max(vals) if vals else 1


def analyze_module(hlo_text: str) -> ModuleStats:
    comps = _parse_computations(hlo_text)
    by_name = {c.name: c for c in comps}

    # call graph with multiplicity factors; fusion interiors excluded from
    # byte/flop accounting via `fusion_interior` marking (dots inside fusions
    # still count — XLA:CPU keeps dots unfused, but be conservative)
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    fusion_comps = set()
    for c in comps:
        for ins in c.instrs:
            if ins.opcode == "while":
                cond = body = None
                for m in _WHILE_ATTRS_RE.finditer(ins.line):
                    cond = m.group(1) or cond
                    body = m.group(2) or body
                trips = _trip_count(ins.line, by_name.get(cond))
                if body in by_name:
                    edges[c.name].append((body, float(max(trips, 1))))
                continue
            if ins.opcode == "fusion":
                for m in _CALLED_RE.finditer(ins.line):
                    if m.group(1) in by_name:
                        fusion_comps.add(m.group(1))
                        edges[c.name].append((m.group(1), 1.0))
                continue
            for m in _CALLED_RE.finditer(ins.line):
                if m.group(1) in by_name:
                    edges[c.name].append((m.group(1), 1.0))
            sm = _CALLED_SET_RE.search(ins.line)
            if sm:
                for nm in sm.group(1).split(","):
                    nm = nm.strip().lstrip("%")
                    if nm in by_name:
                        edges[c.name].append((nm, 1.0))

    mult: Dict[str, float] = defaultdict(float)
    roots = [c.name for c in comps if c.is_entry]
    if not roots and comps:
        roots = [comps[-1].name]
    stack = [(r, 1.0) for r in roots]
    guard = 0
    while stack and guard < 200_000:
        guard += 1
        name, m = stack.pop()
        mult[name] += m
        for child, f in edges.get(name, ()):
            stack.append((child, m * f))

    stats = ModuleStats()
    for c in comps:
        m = mult.get(c.name, 0.0)
        if m <= 0:
            continue
        in_fusion = c.name in fusion_comps
        for ins in c.instrs:
            op = ins.opcode
            if op in _COLLECTIVE_OPS:
                kind = op.replace("-start", "")
                gsize = 0
                gm = _GROUPS_IOTA_RE.search(ins.line)
                if gm:
                    gsize = int(gm.group(2))
                else:
                    gm = _GROUPS_LIST_RE.search(ins.line)
                    if gm:
                        gsize = len([x for x in gm.group(1).split(",") if x.strip()])
                stats.collectives.append(CollectiveOp(
                    kind=kind,
                    result_bytes=_shape_elems_bytes(ins.shape)[1],
                    group_size=max(gsize, 1), multiplicity=m))
            elif op in ("dot", "dot_general"):
                fl = m * _dot_flops(ins, c)
                stats.dot_flops += fl
                stats.contributors.append(Contributor(
                    comp=c.name, opcode=op, shape=ins.shape, multiplicity=m,
                    flops=fl, meta=_op_meta(ins)))
            elif op == "convolution":
                fl = m * _conv_flops(ins, c)
                stats.conv_flops += fl
                stats.contributors.append(Contributor(
                    comp=c.name, opcode=op, shape=ins.shape, multiplicity=m,
                    flops=fl, meta=_op_meta(ins)))
            elif op == "while":
                cond = None
                for wm in _WHILE_ATTRS_RE.finditer(ins.line):
                    cond = wm.group(1) or cond
                stats.while_trip_counts.append(
                    _trip_count(ins.line, by_name.get(cond)))
            if not in_fusion and op not in _COLLECTIVE_OPS:
                by = m * _instr_bytes(ins, c)
                stats.hbm_bytes += by
                if by > 0 and op not in ("dot", "dot_general", "convolution"):
                    stats.contributors.append(Contributor(
                        comp=c.name, opcode=op, shape=ins.shape,
                        multiplicity=m, bytes=by, meta=_op_meta(ins)))
                elif by > 0:
                    # attach bytes to the dot/conv contributor just appended
                    if stats.contributors and stats.contributors[-1].comp == c.name:
                        stats.contributors[-1].bytes += by
    return stats


_META_RE = re.compile(r'op_name="([^"]+)"')


def _op_meta(ins: _Instr) -> str:
    m = _META_RE.search(ins.line)
    return m.group(1)[-90:] if m else ""
