"""Structured engine tracing: nestable spans, counters, instant events.

The serving engine's user-transparency promise cuts both ways — users never
see the runtime, but operators must be able to see *inside* it.  The
TensorFlow whitepaper leans on built-in timeline tracing (EEG) to attribute
step time; this module is that capability for the serving stack: every
engine cycle decomposes into phase spans (preemption check, admission,
chunked prefill, host planning, device decode), every request gets a
lifecycle track (queued -> prefill chunks -> decode -> complete), and the
page pool emits cache events (alloc, COW, ring rotation, LRU traffic,
prefix hit/miss).

Design constraints, in order:

  * **~zero cost when off** — tracing is opt-in (``ServeConfig(trace=True)``).
    The disabled path is :data:`NULL_TRACER`, whose methods are empty and
    whose ``span()`` returns one shared context-manager singleton: no
    allocation, no clock read, no branch in the engine beyond an attribute
    call.  The hot decode loop must not regress when tracing is off.
  * **bounded memory** — events land in a ring buffer (``capacity``, default
    64Ki); when full the *oldest* events drop (``dropped`` counts them), so
    a long-lived engine keeps the recent window instead of growing without
    limit.
  * **deterministic under test** — the clock is injectable
    (``Tracer(clock=...)``), the same pattern ``ServingMetrics`` uses, so
    tests drive exact timelines.

Two kinds of span API:

  * ``with tracer.span("decode.device"): ...`` — lexically scoped phases
    (the engine loop).  Nesting is just lexical nesting; the exporter
    renders it as stacked slices.
  * ``tracer.begin("decode", track=...)`` / ``tracer.end("decode",
    track=...)`` — spans that open and close in *different* engine cycles
    (a request's queued / prefill / decode lifecycle).  ``end`` of a span
    that is not open is a silent no-op (returns False), so preemption
    paths can close "whichever of prefill/decode is open" without
    bookkeeping; balance is checked via :meth:`Tracer.open_spans`.

Per-phase attribution: every closed span accumulates into
``phase_seconds[name]`` / ``phase_counts[name]`` *for the engine track
only* — per-request spans overlap engine phases wall-clock-wise and would
double count.  ``repro.obs.export.phase_snapshot`` flattens those totals
into the dict ``ServingMetrics.summary()`` merges.

Events are stored as plain tuples ``(ph, name, track, ts, value, args)``
with Chrome trace-event phase codes (``"X"`` complete span with
``value=duration``, ``"i"`` instant, ``"C"`` counter with
``value=counter``); ``repro.obs.export`` turns them into a
Perfetto-loadable Chrome trace JSON.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

#: the engine-loop track; phase attribution accumulates spans on it only
ENGINE_TRACK = "engine"

#: event tuple layout (ph, name, track, ts, value, args) — ph follows the
#: Chrome trace-event phase codes so the exporter is a dumb transcription
Event = Tuple[str, str, str, float, float, Optional[Dict[str, Any]]]


class _SpanCtx:
    """Lexically scoped span (``with tracer.span(...)``)."""

    __slots__ = ("_tr", "_name", "_track", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, track: str,
                 args: Optional[Dict[str, Any]]):
        self._tr = tr
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self) -> "_SpanCtx":
        self._t0 = self._tr._clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tr
        tr._span(self._name, self._track, self._t0, tr._clock(), self._args)
        return False


class Tracer:
    """Bounded-ring span/counter/instant recorder with per-phase totals."""

    enabled = True

    def __init__(self, *, clock: Optional[Callable[[], float]] = None,
                 capacity: int = 1 << 16,
                 meta: Optional[Dict[str, Any]] = None):
        assert capacity >= 1, capacity
        self._clock = clock or time.perf_counter
        self.capacity = capacity
        self.meta: Dict[str, Any] = dict(meta or {})
        self.reset()

    def reset(self) -> None:
        """Drop every event and phase total (benchmarks reuse warm engines;
        the clock, capacity and meta survive)."""
        self.events: deque = deque()
        self.dropped = 0
        self._open: Dict[Tuple[str, str], Tuple[float,
                                                Optional[Dict[str, Any]]]] = {}
        self.phase_seconds: Dict[str, float] = {}
        self.phase_counts: Dict[str, int] = {}
        self.t0 = self._clock()

    def now(self) -> float:
        return self._clock()

    # -- recording ---------------------------------------------------------

    def _push(self, ev: Event) -> None:
        if len(self.events) >= self.capacity:
            self.events.popleft()
            self.dropped += 1
        self.events.append(ev)

    def _span(self, name: str, track: str, t0: float, t1: float,
              args: Optional[Dict[str, Any]]) -> None:
        self._push(("X", name, track, t0, t1 - t0, args))
        if track == ENGINE_TRACK:
            self.phase_seconds[name] = \
                self.phase_seconds.get(name, 0.0) + (t1 - t0)
            self.phase_counts[name] = self.phase_counts.get(name, 0) + 1

    def span(self, name: str, track: str = ENGINE_TRACK,
             **args: Any) -> _SpanCtx:
        """Lexically scoped span; nest freely (``with`` blocks)."""
        return _SpanCtx(self, name, track, args or None)

    def begin(self, name: str, track: str = ENGINE_TRACK,
              **args: Any) -> None:
        """Open a cross-cycle span.  Re-opening an already open (track,
        name) closes the stale one first (balance over silent leaks)."""
        key = (track, name)
        stale = self._open.pop(key, None)
        if stale is not None:
            self._span(name, track, stale[0], self._clock(),
                       dict(stale[1] or {}, reopened=True))
        self._open[key] = (self._clock(), args or None)

    def end(self, name: str, track: str = ENGINE_TRACK, **args: Any) -> bool:
        """Close a cross-cycle span; False (and no event) when it is not
        open — callers may unconditionally close alternatives."""
        o = self._open.pop((track, name), None)
        if o is None:
            return False
        merged = dict(o[1] or {})
        merged.update(args)
        self._span(name, track, o[0], self._clock(), merged or None)
        return True

    def instant(self, name: str, track: str = ENGINE_TRACK,
                **args: Any) -> None:
        """Point event (page alloc, COW, preemption, compile, ...)."""
        self._push(("i", name, track, self._clock(), 0.0, args or None))

    def counter(self, name: str, value: float,
                track: str = ENGINE_TRACK) -> None:
        """Sampled counter series (queue depth, pages held, ...)."""
        self._push(("C", name, track, self._clock(), float(value), None))

    # -- inspection --------------------------------------------------------

    def open_spans(self) -> List[Tuple[str, str]]:
        """(track, name) of every begin() without a matching end() — the
        balance tests assert this drains to [] when the engine drains."""
        return sorted(self._open)

    def close_all(self, **args: Any) -> int:
        """Close every open cross-cycle span (export hygiene for traces
        snapshotted mid-flight); returns how many were closed."""
        n = 0
        for track, name in list(self._open):
            self.end(name, track=track, **args)
            n += 1
        return n


class _NullSpan:
    """The shared no-op context manager ``NULL_TRACER.span`` hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()
_EMPTY_DICT: Dict[str, Any] = {}


class NullTracer:
    """Strict no-op twin of :class:`Tracer` — the disabled hot path.

    Every method returns immediately without reading the clock or
    allocating; ``span()`` returns one module-level singleton context
    manager.  ``events`` / ``phase_seconds`` present the empty shapes so
    consumers (metrics merge, exporters) need no enabled-check branches.
    """

    enabled = False
    events: Tuple[Event, ...] = ()
    dropped = 0
    capacity = 0
    t0 = 0.0
    meta = _EMPTY_DICT
    phase_seconds: Dict[str, float] = _EMPTY_DICT
    phase_counts: Dict[str, int] = _EMPTY_DICT

    def reset(self) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def span(self, name: str, track: str = ENGINE_TRACK,
             **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def begin(self, name: str, track: str = ENGINE_TRACK,
              **args: Any) -> None:
        pass

    def end(self, name: str, track: str = ENGINE_TRACK, **args: Any) -> bool:
        return False

    def instant(self, name: str, track: str = ENGINE_TRACK,
                **args: Any) -> None:
        pass

    def counter(self, name: str, value: float,
                track: str = ENGINE_TRACK) -> None:
        pass

    def open_spans(self) -> List[Tuple[str, str]]:
        return []

    def close_all(self, **args: Any) -> int:
        return 0


#: the one NullTracer every disabled engine shares
NULL_TRACER = NullTracer()


def request_track(rid: int) -> str:
    """Track name of one request's lifecycle spans (one Perfetto row per
    request, per the whitepaper-style timeline view)."""
    return f"req{rid}"
