"""repro.obs — engine tracing and per-phase attribution.

The observability counterpart of the serving stack's user-transparency:
operators flip ``ServeConfig(trace=True)`` (or ``--trace out.json`` on any
launch entrypoint) and every serving cycle explains itself — phase spans on
the engine track, lifecycle spans per request, cache events from the page
pool — exportable as a Perfetto-loadable Chrome trace or folded into
``ServingMetrics.summary()`` as flat per-phase seconds.

Import discipline: this package depends on the standard library only (no
jax, no numpy) — it sits below every serving module that emits into it.
"""
from repro.obs.export import (HOST_OVERHEAD_FRAC, INFLIGHT_COUNTER,
                              LEAF_PHASES, PHASE_TIME_KEYS, STEP_SECTIONS,
                              TRACED_ONLY_KEYS, chrome_trace,
                              phase_coverage, phase_snapshot,
                              prometheus_text, write_chrome_trace)
from repro.obs.trace import (ENGINE_TRACK, NULL_TRACER, NullTracer, Tracer,
                             request_track)

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "ENGINE_TRACK",
           "request_track", "chrome_trace", "write_chrome_trace",
           "phase_snapshot", "phase_coverage", "prometheus_text",
           "STEP_SECTIONS", "LEAF_PHASES", "INFLIGHT_COUNTER",
           "PHASE_TIME_KEYS", "TRACED_ONLY_KEYS", "HOST_OVERHEAD_FRAC"]
