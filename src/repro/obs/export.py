"""Trace exporters: Chrome trace-event JSON (Perfetto) + flat snapshots.

Two consumers, two shapes:

  * **Chrome trace JSON** (:func:`chrome_trace` / :func:`write_chrome_trace`)
    — the ``{"traceEvents": [...]}`` format `Perfetto <https://ui.perfetto.
    dev>`_ (and ``chrome://tracing``) loads directly.  One track (tid) for
    the engine loop, one per request; spans are complete events (``"X"``),
    cache/pool happenings are instants (``"i"``), sampled series (queue
    depth, pages held) are counters (``"C"``).  Timestamps are microseconds
    relative to the tracer's epoch.  Every event carries ``ph/ts/pid/tid``
    — asserted by the schema test and the CI smoke gate.
  * **flat phase snapshot** (:func:`phase_snapshot`) — the per-phase time
    totals as plain floats, merged into ``ServingMetrics.summary()`` so
    one JSON record answers "where did the cycle go" without opening a
    trace; :func:`prometheus_text` renders the same summary as a
    Prometheus-style text exposition for scrape-shaped consumers.

Phase model (engine track span names):

  * ``step`` wraps one engine cycle; the pipeline *sections* ``step.plan``
    (pure host planning: scheduler decisions, admission, page-table and
    chunk construction), ``step.draft`` (host n-gram drafting for
    speculative decoding — near-zero when spec is off), ``step.submit``
    (device dispatch of the plan) and ``step.retire`` (materialize a
    completed cycle's tokens: stream, completion, page frees) tile it
    (:data:`STEP_SECTIONS` — their sum over a run is the cycle wall time
    minus loop glue, asserted >= 95% by the tests).  With
    ``pipeline_depth=2`` a step's retire section drains the *previous*
    cycle, so in a trace submit(N+1) begins before retire(N) ends — the
    overlap the ``engine.inflight`` counter makes visible in Perfetto;
  * the *leaves* ``plan`` (host-side prefix planning / page bookkeeping,
    nested under whichever section triggered it), ``prefill.device``,
    ``decode.device`` and ``verify.device`` (jitted calls, fenced with
    ``block_until_ready`` in traced mode) are mutually disjoint, so
    ``other = step - plan - step.draft - prefill.device - decode.device
    - verify.device`` is the well-defined "everything else" — scheduling,
    numpy glue, stream callbacks — and ``host_overhead_frac = other /
    step`` is the number the async-pipeline work drives down (gated
    <= 0.25 by the CI smoke).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.trace import ENGINE_TRACK

#: engine-track spans that tile one ``step`` span (coverage denominator)
STEP_SECTIONS = ("step.plan", "step.draft", "step.submit", "step.retire")

#: disjoint leaf phases the summary attributes wall time to
LEAF_PHASES = ("plan", "prefill.device", "decode.device", "verify.device")

#: Perfetto counter track: device cycles submitted but not yet retired
INFLIGHT_COUNTER = "engine.inflight"

#: named phase keys shared by :func:`phase_snapshot`,
#: ``ServingMetrics.summary()`` and the bench schema gate — one spelling,
#: three consumers, no drift
STEP_TIME_S = "step_time_s"
PLAN_TIME_S = "plan_time_s"
DRAFT_TIME_S = "draft_time_s"
PREFILL_TIME_S = "prefill_time_s"
DECODE_TIME_S = "decode_time_s"
VERIFY_TIME_S = "verify_time_s"
OTHER_TIME_S = "other_time_s"
HOST_OVERHEAD_FRAC = "host_overhead_frac"
PHASE_TIME_KEYS = (STEP_TIME_S, PLAN_TIME_S, DRAFT_TIME_S, PREFILL_TIME_S,
                   DECODE_TIME_S, VERIFY_TIME_S, OTHER_TIME_S)
#: phase-derived summary keys that are meaningless untraced (the traced
#: attribution pass owns them; untraced bench records must omit them)
TRACED_ONLY_KEYS = PHASE_TIME_KEYS + (
    HOST_OVERHEAD_FRAC, "decode_tokens_per_sec", "prefill_tokens_per_sec")


def chrome_trace(tracer, *, pid: int = 1) -> Dict[str, Any]:
    """Convert a tracer's ring buffer into a Chrome trace-event dict.

    Still-open cross-cycle spans (a trace snapshotted mid-serve) are
    emitted as spans up to ``now`` with ``args.unfinished = true`` rather
    than dangling ``"B"`` events Perfetto would render unmatched.
    """
    t0 = tracer.t0
    tids: Dict[str, int] = {ENGINE_TRACK: 0}
    events: List[Dict[str, Any]] = []

    def tid(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids)
        return tids[track]

    def us(t: float) -> float:
        return (t - t0) * 1e6

    for ph, name, track, ts, value, args in tracer.events:
        ev: Dict[str, Any] = {"name": name, "ph": ph, "ts": us(ts),
                              "pid": pid, "tid": tid(track),
                              "cat": "serving"}
        if ph == "X":
            ev["dur"] = value * 1e6
            if args:
                ev["args"] = args
        elif ph == "i":
            ev["s"] = "t"                      # thread-scoped instant
            if args:
                ev["args"] = args
        elif ph == "C":
            ev["args"] = {"value": value}
        events.append(ev)
    if getattr(tracer, "_open", None):
        now = tracer.now()
        for (track, name), (ts, args) in sorted(tracer._open.items()):
            events.append({"name": name, "ph": "X", "ts": us(ts),
                           "dur": (now - ts) * 1e6, "pid": pid,
                           "tid": tid(track), "cat": "serving",
                           "args": dict(args or {}, unfinished=True)})
    meta_events = [{"name": "process_name", "ph": "M", "ts": 0, "pid": pid,
                    "tid": 0, "args": {"name": "repro.serving"}}]
    for track, t in sorted(tids.items(), key=lambda kv: kv[1]):
        meta_events.append({"name": "thread_name", "ph": "M", "ts": 0,
                            "pid": pid, "tid": t, "args": {"name": track}})
        meta_events.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                            "pid": pid, "tid": t,
                            "args": {"sort_index": t}})
    return {"traceEvents": meta_events + events,
            "displayTimeUnit": "ms",
            "otherData": dict(tracer.meta, dropped_events=tracer.dropped)}


def write_chrome_trace(tracer, path: str, *, pid: int = 1) -> str:
    """Serialize :func:`chrome_trace` to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer, pid=pid), f)
    return path


def phase_snapshot(tracer) -> Dict[str, float]:
    """Flat per-phase attribution totals (seconds) for the summary merge.

    ``*_time_s`` keys are the disjoint leaves plus the enclosing ``step``
    wall; ``other_time_s`` is step minus the leaves — host scheduling,
    numpy glue, stream callbacks.  All zeros for a :class:`NullTracer`
    (tracing off), so the summary schema is stable either way.
    """
    ph = tracer.phase_seconds
    step = ph.get("step", 0.0)
    plan = ph.get("plan", 0.0)
    draft = ph.get("step.draft", 0.0)
    prefill = ph.get("prefill.device", 0.0)
    decode = ph.get("decode.device", 0.0)
    verify = ph.get("verify.device", 0.0)
    other = max(step - plan - draft - prefill - decode - verify, 0.0)
    return {
        STEP_TIME_S: step,
        PLAN_TIME_S: plan,
        DRAFT_TIME_S: draft,
        PREFILL_TIME_S: prefill,
        DECODE_TIME_S: decode,
        VERIFY_TIME_S: verify,
        OTHER_TIME_S: other,
        HOST_OVERHEAD_FRAC: (other / step) if step > 0 else 0.0,
    }


def phase_coverage(tracer) -> float:
    """Fraction of engine-loop wall time the section spans account for
    (the acceptance bar: >= 0.95 on a traced smoke serve).  1.0 when
    nothing was traced — an empty trace has no unattributed time."""
    ph = tracer.phase_seconds
    step = ph.get("step", 0.0)
    if step <= 0.0:
        return 1.0
    return min(sum(ph.get(s, 0.0) for s in STEP_SECTIONS) / step, 1.0)


def prometheus_text(summary: Dict[str, Any], tracer=None,
                    prefix: str = "repro_serving") -> str:
    """Prometheus-style text exposition of a ``ServingMetrics.summary()``
    dict (numeric fields only), plus per-phase seconds as one labelled
    series when a tracer is supplied."""
    lines = [f"# {prefix}: serving engine snapshot"]
    for k, v in summary.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        lines.append(f"{prefix}_{k} {v}")
    if tracer is not None:
        for name, secs in sorted(tracer.phase_seconds.items()):
            lines.append(
                f'{prefix}_phase_seconds{{phase="{name}"}} {secs}')
            lines.append(
                f'{prefix}_phase_calls{{phase="{name}"}} '
                f"{tracer.phase_counts.get(name, 0)}")
    return "\n".join(lines) + "\n"


__all__ = ["chrome_trace", "write_chrome_trace", "phase_snapshot",
           "phase_coverage", "prometheus_text", "STEP_SECTIONS",
           "LEAF_PHASES", "INFLIGHT_COUNTER", "PHASE_TIME_KEYS",
           "TRACED_ONLY_KEYS", "STEP_TIME_S", "PLAN_TIME_S",
           "DRAFT_TIME_S", "PREFILL_TIME_S", "DECODE_TIME_S",
           "VERIFY_TIME_S", "OTHER_TIME_S", "HOST_OVERHEAD_FRAC"]
