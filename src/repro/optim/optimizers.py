"""Optimizer substrate (paper §I names AdaGrad, Adam, Momentum SGD).

Functional, pytree-based, self-contained (no optax offline):

    opt = make_optimizer(OptimizerConfig(name="adam", lr=1e-3))
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

All states/updates are fp32 ("master weights"); callers cast params to the
compute dtype inside the loss (mixed precision).  ``update`` consumes fp32
gradients.  Flat-shard variants (for the ZeRO-1 reduce_scatter strategy)
operate on 1-D fp32 vectors with the same math.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]   # (grads, state, params)
    cfg: OptimizerConfig


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------

def _sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        new = _tmap(lambda p, g: p - cfg.lr * g.astype(p.dtype), params, grads)
        return new, {"step": state["step"] + 1}

    return Optimizer(init, update, cfg)


def _momentum(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params):
        v = _tmap(lambda v, g: cfg.momentum * v + g.astype(jnp.float32),
                  state["v"], grads)
        new = _tmap(lambda p, v: p - cfg.lr * v.astype(p.dtype), params, v)
        return new, {"step": state["step"] + 1, "v": v}

    return Optimizer(init, update, cfg)


# ---------------------------------------------------------------------------
# AdaGrad
# ---------------------------------------------------------------------------

def _adagrad(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "acc": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params):
        acc = _tmap(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                    state["acc"], grads)
        new = _tmap(
            lambda p, g, a: p - (cfg.lr * g.astype(jnp.float32)
                                 / (jnp.sqrt(a) + cfg.eps)).astype(p.dtype),
            params, grads, acc)
        return new, {"step": state["step"] + 1, "acc": acc}

    return Optimizer(init, update, cfg)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

def _adam(cfg: OptimizerConfig, decoupled_wd: bool) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params):
        t = state["step"] + 1
        b1, b2 = cfg.beta1, cfg.beta2
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m, v):
            step = cfg.lr * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            if decoupled_wd and cfg.weight_decay:
                step = step + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype)

        new = _tmap(upd, params, m, v)
        return new, {"step": t, "m": m, "v": v}

    return Optimizer(init, update, cfg)


_FACTORY = {
    "sgd": lambda c: _sgd(c),
    "momentum": lambda c: _momentum(c),
    "adagrad": lambda c: _adagrad(c),
    "adam": lambda c: _adam(c, False),
    "adamw": lambda c: _adam(c, True),
}


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    try:
        fac = _FACTORY[cfg.name]
    except KeyError:
        raise KeyError(f"unknown optimizer {cfg.name!r}: {sorted(_FACTORY)}") from None
    return fac(cfg)


def opt_state_specs(opt: Optimizer, param_specs):
    """ParamSpec-shaped ShapeDtypeStructs for the optimizer state (dry-run)."""
    structs = jax.eval_shape(
        opt.init,
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
                     param_specs,
                     is_leaf=lambda x: hasattr(x, "axes")))
    return structs
